"""Analytic roofline terms for the LM cells.

XLA's CPU HloCostAnalysis counts while-loop bodies ONCE (verified:
scan-of-matmul flops are length-independent), so the scan-over-layers LM
cells undercount flops/bytes/collective-bytes by the trip counts.  These
closed-form terms mirror our implementation op-for-op (same chunked
attention, same MoE dispatch einsums, same sharding rules) and are the
§Roofline numbers for LM cells; the measured HLO values are reported
alongside as `hlo_*` (lower bounds, loop bodies once).

Conventions:
  train factors: matmul fwd=2·m·n·k; bwd=2×fwd; remat re-fwd=+1×fwd → 4×.
  attention tile flops are NOT causally skipped (the baseline masks, it
  does not skip — exactly what causal block pairing later removes).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import LMConfig
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

DTYPE = 2  # bf16


def _per_layer_matmul_flops(cfg: LMConfig, tokens: int) -> float:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    attn = 2 * tokens * d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.moe is not None:
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        ffn = n_mats * 2 * tokens * cfg.moe.top_k * d * cfg.moe.d_ff
        ffn += 2 * tokens * d * cfg.moe.n_experts          # router
    else:
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        ffn = n_mats * 2 * tokens * d * cfg.d_ff
    return attn + ffn


def _attn_score_flops(cfg: LMConfig, batch: int, s_q: int, s_kv: int) -> float:
    dh = cfg.resolved_head_dim
    return 2 * 2 * batch * cfg.n_heads * s_q * s_kv * dh   # QK^T + PV


def lm_analytic(cfg: LMConfig, step: str, dims: Dict[str, int],
                n_chips: int = 256, data_par: int = 16,
                causal_block_pairing: bool = False,
                seq_parallel: bool = False,
                overlap_collectives: bool = False,
                selective_recompute: float = 1.0,
                selective_decode_read: float = 1.0) -> Dict[str, float]:
    """Hillclimb knobs:
      causal_block_pairing  — skip fully-masked causal tiles (≈0.55× attn)
      seq_parallel          — Megatron-SP boundaries: the 2 per-block
                              all-reduces become reduce-scatter + all-gather
                              over sequence-sharded activations (×0.5 wire)
      overlap_collectives   — async collectives hidden behind compute:
                              effective time = max(comp, coll) instead of sum
                              (reported via `overlapped_s`)
      selective_recompute   — RcLLM prefill: fraction of tokens recomputed
                              beyond layer 0 (the paper's own technique)
    """
    b, s = dims["batch"], dims["seq"]
    L = cfg.n_layers

    if step == "train":
        tokens = b * s
        mm = L * _per_layer_matmul_flops(cfg, tokens)
        att = L * _attn_score_flops(cfg, b, s, s)
        if causal_block_pairing:
            att *= 0.55                     # live tiles ≈ (nq·nk/2 + diag)
        head = 2 * tokens * cfg.d_model * cfg.vocab_size
        total = 4.0 * (mm + att) + 3.0 * head       # fwd+2bwd+remat / no-remat head
        flops_dev = total / n_chips

        p_total = cfg.param_count()
        p_local = p_total * DTYPE / n_chips          # fully sharded weights
        act_layer = tokens * cfg.d_model * DTYPE / data_par
        opt_bytes = (2 if cfg.optimizer == "adafactor" else 8) * \
            p_total / n_chips * (1 if cfg.optimizer == "adafactor" else 1)
        # params read 3× (fwd/bwd/remat) + grads written + opt r/w +
        # residual stack write+read + per-layer activation traffic (~6 big
        # tensors r/w per layer in the fused pipeline)
        bytes_dev = (3 * p_local + 2 * p_local + 2 * opt_bytes
                     + 2 * L * act_layer + 6 * L * act_layer)
        # collectives per device: DP grad all-reduce (2×local shard) +
        # TP all-reduce of (B_loc, S, D) twice per layer fwd + 2× bwd
        dp = 2.0 * p_local
        tp = 4 * L * act_layer * 2.0
        if seq_parallel:
            tp *= 0.5
        coll_dev = dp + tp
        if cfg.moe is not None:
            # EP dispatch/combine ≈ all-to-all of top_k·tokens·D in+out,
            # fwd and bwd
            ep = 4.0 * cfg.moe.top_k * tokens * cfg.d_model * DTYPE / n_chips
            coll_dev += ep

    elif step == "prefill":
        tokens = b * s
        r = selective_recompute
        # RcLLM: layer 0 runs for every token; layers 1..L-1 only for the
        # recompute set, whose attention reads all keys (r·S² scores)
        mm = (_per_layer_matmul_flops(cfg, tokens)
              + (L - 1) * _per_layer_matmul_flops(cfg, int(r * tokens)))
        att0 = _attn_score_flops(cfg, b, s, s)
        att_rest = (L - 1) * _attn_score_flops(cfg, b, int(r * s), s)
        att = att0 + att_rest
        if causal_block_pairing:
            att *= 0.55
        head = 2 * b * cfg.d_model * cfg.vocab_size   # last position only
        total = mm + att + head
        flops_dev = total / n_chips
        p_local = cfg.param_count() * DTYPE / n_chips
        act_layer = tokens * cfg.d_model * DTYPE / data_par
        kv_bytes = (2 * L * tokens * cfg.n_kv_heads * cfg.resolved_head_dim
                    * DTYPE / n_chips)
        bytes_dev = p_local + 6 * L * act_layer * (1 + r * (L - 1)) / L \
            + kv_bytes
        coll_dev = 2 * L * act_layer * 1.0            # TP all-reduce fwd only
        if seq_parallel:
            coll_dev *= 0.5
        if cfg.moe is not None:
            coll_dev += 2.0 * cfg.moe.top_k * tokens * cfg.d_model * DTYPE \
                / n_chips

    else:                                             # decode
        tokens = b                                    # one token per sequence
        rd = selective_decode_read        # RcLLM read set: (window ∪ HH)/S
        mm = L * _per_layer_matmul_flops(cfg, tokens)
        att = L * _attn_score_flops(cfg, b, 1, int(rd * s))
        head = 2 * b * cfg.d_model * cfg.vocab_size
        total = mm + att + head
        flops_dev = total / n_chips
        # decode is memory-bound: read every local param + the local KV slice
        p_local = cfg.param_count() * DTYPE / n_chips
        kv_local = (2 * L * b * s * cfg.n_kv_heads * cfg.resolved_head_dim
                    * DTYPE / n_chips) * rd
        bytes_dev = p_local + kv_local
        act = b * cfg.d_model * DTYPE / max(data_par, 1)
        coll_dev = 2 * L * act                        # TP combine per layer
        if cfg.moe is not None:
            coll_dev += 2.0 * cfg.moe.top_k * tokens * cfg.d_model * DTYPE \
                / n_chips

    ct, mt, xt = (flops_dev / PEAK_FLOPS, bytes_dev / HBM_BW,
                  coll_dev / ICI_BW)
    terms = {"compute_s": ct, "memory_s": mt, "collective_s": xt,
             "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
             "collective_bytes_per_device": coll_dev}
    terms["bottleneck"] = max(
        (("compute", ct), ("memory", mt), ("collective", xt)),
        key=lambda kv: kv[1])[0]
    dom = max(ct, mt, xt)
    terms["roofline_fraction"] = ct / dom if dom > 0 else 0.0
    terms["serial_s"] = ct + mt + xt
    terms["overlapped_s"] = max(ct, max(mt, xt)) if overlap_collectives \
        else ct + mt + xt
    return terms
