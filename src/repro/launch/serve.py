"""Serving launcher: cluster simulation or the real batched JAX engine.

    # distributed cluster simulation (analytic cost model, K instances)
    PYTHONPATH=src python -m repro.launch.serve --k 40 --qps 120

    # real hardware: continuous batching + paged KV pool on one instance
    PYTHONPATH=src python -m repro.launch.serve --engine jax --requests 8 --k 1

    # real hardware, K instances: affinity-scheduled cluster of JAX
    # engines over sharded item caches (per-request TTFT, per-worker
    # hit rates, explicit cross-shard transfers)
    PYTHONPATH=src python -m repro.launch.serve --engine jax --k 4 \\
        --requests 12 --mode rcllm

    # unified token-budget scheduler: chunk-resumable selective prefill
    # mixed with decode in every tick (no whole-prefill waves)
    PYTHONPATH=src python -m repro.launch.serve --engine jax --requests 12 \\
        --sched chunked --chunk-tokens 128 --long-prompt-frac 0.2

All paths drive the *same* batching loop; `--engine` picks the backend
behind its seam (`serving.batching.EngineBackend`) and `--k` with
``--engine jax`` picks single-instance vs the `serving.cluster` path.
``--sched`` picks the scheduling discipline: ``wave`` (whole-prefill
batches, prefill-prioritized — the default) or ``chunked`` (every tick
packs decode tokens plus fixed-size prefill chunks under a global token
budget; decoded tokens are bitwise identical either way).  With
``--mode rcllm`` each prompt goes through decomposition → assembly
plan → beyond-prefix cache insertion → selective recompute → paged
decode; ``--mode full`` is the Full-Recompute reference.  See
examples/serve_cluster.py for the narrated simulator; this entry point
emits machine-readable JSON, including a per-request latency split
(queue-wait vs prefill-compute vs decode) and time-between-tokens
percentiles so scheduler changes are attributable from bench artifacts.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM


def run_sim(args) -> dict:
    qps = args.qps if args.qps is not None else 3.0 * args.k
    cfg = REG.ARCHS[args.model]
    reqs, placement, _ = SIM.make_sim_setup(
        k=args.k, n_requests=args.requests, qps=qps, n_items=8000, seed=1
    )
    res = SIM.simulate(
        cfg,
        CM.V5E_1,
        reqs,
        placement,
        SIM.SimConfig(
            mode=args.mode,
            policy=args.policy,
            r_item=args.r_item,
            r_rev=args.r_rev,
        ),
    )
    return {
        "engine": "sim",
        "k": args.k,
        "qps": qps,
        "mode": args.mode,
        "policy": args.policy,
        **res.summary(),
    }


def _percentiles(xs, qs=(50, 90, 99)) -> dict:
    xs = np.asarray(list(xs), np.float64)
    if len(xs) == 0:
        return {f"p{q}_s": None for q in qs}
    return {f"p{q}_s": float(np.percentile(xs, q)) for q in qs}


def _latency_split(completions) -> dict:
    """Per-request latency attribution + aggregates from completions."""
    done = sorted(completions, key=lambda c: c.rid)
    ttft = np.asarray([c.first_token_s - c.arrival_s for c in done])
    return {
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p90_s": float(np.percentile(ttft, 90)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "ttft_mean_s": float(ttft.mean()),
        "queue_wait_mean_s": float(np.mean([c.queue_wait_s for c in done])),
        "prefill_mean_s": float(np.mean([c.prefill_s for c in done])),
        "decode_mean_s": float(np.mean([c.decode_s for c in done])),
        "per_request": [
            {
                "rid": c.rid,
                "ttft_s": round(float(c.first_token_s - c.arrival_s), 4),
                "queue_wait_s": round(float(c.queue_wait_s), 4),
                "prefill_s": round(float(c.prefill_s), 4),
                "decode_s": round(float(c.decode_s), 4),
            }
            for c in done
        ],
    }


def _tbt_stats(workers) -> dict:
    samples = [dt for w in workers for dt in w.tbt]
    out = {f"tbt_{k}": v for k, v in _percentiles(samples).items()}
    out["tbt_samples"] = len(samples)
    return out


def _tick_stats(workers) -> dict:
    ticks = [t for w in workers for t in w.ticks]
    if not ticks:
        return {}
    return {
        "ticks": len(ticks),
        "oversized_ticks": sum(1 for t in ticks if t.oversized),
        "mean_tick_tokens": float(
            np.mean(
                [t.decode_tokens + t.chunk_tokens + t.finalize_tokens
                 for t in ticks]
            )
        ),
    }


def _check_jax_flags(args) -> None:
    if args.mode == "prefix":
        raise SystemExit(
            "--engine jax supports --mode rcllm|full "
            "(prefix caching is a simulator-only baseline)"
        )
    if args.kv_reuse == "on" and args.mode != "rcllm":
        raise SystemExit(
            "--kv-reuse on needs --mode rcllm (the shared "
            "block store holds beyond-prefix blocks)"
        )
    if args.sched == "chunked" and args.mode != "rcllm":
        raise SystemExit(
            "--sched chunked drives the beyond-prefix selective "
            "prefill; --mode full has no chunk-resumable path"
        )


def run_jax_cluster(args) -> dict:
    """K real engine workers behind the Eq. 2 scheduler (serving.cluster)."""
    from repro.core.rcllm import make_tiny_system
    from repro.data import synth as SY
    from repro.serving.cluster import ClusterEngine

    _check_jax_flags(args)
    qps = args.qps if args.qps is not None else 8.0
    system, pool_rv, prof, _ = make_tiny_system(
        n_items=80, n_requests_hist=40, k_instances=args.k,
        n_layers=2, d_model=32,
    )
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        args.requests,
        qps=qps,
        n_users=max(3, args.requests // 2),
        n_candidates=8,
        reviews_per_user=1,
        seed=2,
        user_zipf_a=args.zipf_users,
        long_prompt_frac=args.long_prompt_frac,
    )

    def make_cluster():
        return ClusterEngine(
            system,
            k=args.k,
            mode=args.mode,
            policy=args.policy,
            page_size=args.page_size,
            n_pages=args.pages,
            max_batch_tokens=args.max_batch_tokens,
            attn_backend=args.attn_backend,
            decode_kernel=args.decode_kernel,
            kv_reuse=args.kv_reuse == "on",
            sched=args.sched,
            chunk_tokens=args.chunk_tokens,
            step_tokens=args.step_tokens,
        )

    if args.warmup:
        make_cluster().run(trace, decode_steps=args.decode_steps)
    cluster = make_cluster()
    rep = cluster.run(trace, decode_steps=args.decode_steps)

    ttft = rep.ttft()
    return {
        "engine": "jax-cluster",
        "k": args.k,
        "mode": args.mode,
        "sched": args.sched,
        "attn_backend": args.attn_backend,
        "decode_kernel": args.decode_kernel,
        "kv_reuse": args.kv_reuse,
        "policy": rep.policy,
        "requests": len(rep.completions),
        "decode_steps": args.decode_steps,
        "includes_jit_compile": not args.warmup,
        "per_request_ttft_s": [round(float(x), 4) for x in ttft],
        **_latency_split(rep.completions),
        **_tbt_stats(cluster.batcher.workers),
        **_tick_stats(cluster.batcher.workers),
        "mean_hit_rate": rep.mean_hit_rate(),
        "per_worker": [
            {
                "worker": w.worker,
                "requests": w.n_requests,
                "mean_hit_rate": (
                    round(w.mean_hit_rate, 4)
                    if w.mean_hit_rate is not None
                    else None
                ),
                "transfer_blocks": w.transfer_blocks,
                "transfer_tokens": w.transfer_tokens,
                "transfer_mbytes": round(w.transfer_bytes / 1e6, 3),
                "transfer_seconds": round(w.transfer_seconds, 6),
                "pool_peak_pages": w.pool_peak_pages,
                "busy_seconds": round(w.busy_seconds, 4),
                "preempted": w.preempted,
                "kv_reuse": w.kv_reuse,
            }
            for w in rep.workers
        ],
    }


def run_jax(args) -> dict:
    """Continuous batching over the real engine on this host's devices."""
    import dataclasses

    from repro.core import engine as ENG
    from repro.serving.batch_engine import BatchEngine
    from repro.serving.batching import (
        ContinuousBatcher,
        JaxEngineBackend,
        PendingRequest,
    )
    from repro.serving.kv_pool import pool_for
    from repro.serving.workload import rcllm_workload

    _check_jax_flags(args)
    if args.zipf_users is not None and args.mode != "rcllm":
        raise SystemExit(
            "--zipf-users shapes the rcllm trace; it has no "
            "effect on --mode full prompts"
        )
    qps = args.qps if args.qps is not None else 8.0
    rng = np.random.default_rng(1)
    mode = args.mode
    plans = {}
    reuse = None

    if mode == "rcllm":
        # full RcLLM stack: tiny model + both cache pools + placement
        from repro.core.rcllm import make_tiny_system
        from repro.data import synth as SY
        from repro.serving.workload import rcllm_reuse_info

        system, pool_rv, prof, _ = make_tiny_system(
            n_items=80, n_requests_hist=40, k_instances=max(args.k, 1),
            n_layers=2, d_model=32,
        )
        params, cfg = system.params, system.cfg
        # one trace producer for every flag combination: --zipf-users
        # changes ONLY the user-id distribution and --long-prompt-frac
        # ONLY the history-length tail, so scheduler / reuse comparisons
        # are not confounded by trace shape
        trace = SY.make_trace(
            system.catalog,
            pool_rv,
            prof,
            args.requests,
            qps=qps,
            n_users=max(3, args.requests // 2),
            n_candidates=8,
            reviews_per_user=1,
            seed=2,
            user_zipf_a=args.zipf_users,
            long_prompt_frac=args.long_prompt_frac,
        )
        reqs, plans = rcllm_workload(system, trace, decode_steps=args.decode_steps)
        if args.kv_reuse == "on":
            reuse = rcllm_reuse_info(system, trace, plans)
    else:
        # Full-Recompute reference on random prompts
        import jax

        from repro.configs.base import LMConfig
        from repro.models import transformer as T

        cfg = LMConfig(
            name="serve-tiny",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            mlp_type="swiglu",
            dtype="float32",
            attn_q_chunk=64,
            attn_kv_chunk=64,
            remat=False,
        )
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        if args.prompt_tokens < 16:
            raise SystemExit("--prompt-tokens must be >= 16")
        lo = min(48, args.prompt_tokens)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, args.requests))
        reqs = []
        for rid in range(args.requests):
            n = int(rng.integers(lo, args.prompt_tokens + 1))
            reqs.append(
                PendingRequest(
                    arrival_s=float(arrivals[rid]),
                    rid=rid,
                    n_tokens=n,
                    decode_steps=args.decode_steps,
                    tokens=rng.integers(1, cfg.vocab_size, n).astype(np.int32),
                )
            )

    # the attention-backend seam: jnp reference vs Pallas kernels inside
    # the engine's jitted prefill/decode steps (offline caches above were
    # built with the default backend; their pre-RoPE bytes are
    # backend-invariant)
    cfg = dataclasses.replace(
        cfg, attn_backend=args.attn_backend, decode_kernel=args.decode_kernel
    )

    def make_batcher():
        from repro.serving.block_store import SharedBlockStore

        pool = pool_for(cfg, page_size=args.page_size, n_pages=args.pages)
        engine = BatchEngine(
            params,
            cfg,
            pool=pool,
            sel=ENG.SelectiveConfig(r_item=args.r_item, r_rev=args.r_rev, window=16),
            store=(SharedBlockStore(pool) if args.kv_reuse == "on" else None),
            chunk_tokens=args.chunk_tokens,
        )
        backend = JaxEngineBackend(engine, mode=mode, plans=plans, reuse=reuse)
        return engine, backend, ContinuousBatcher(
            backend=backend,
            max_batch_tokens=args.max_batch_tokens,
            sched=args.sched,
            chunk_tokens=args.chunk_tokens,
            step_tokens=args.step_tokens,
        )

    if args.warmup:
        # throwaway pass to fill the jit caches, so the reported times
        # are step times rather than trace/compile times
        make_batcher()[2].run(list(reqs))
    engine, backend, batcher = make_batcher()
    done = sorted(batcher.run(reqs), key=lambda c: c.rid)

    total = max(c.done_s for c in done)
    n_toks = sum(len(backend.generated[c.rid]) for c in done)
    stats = engine.pool.stats()
    out = {
        "engine": "jax",
        "mode": mode,
        "sched": args.sched,
        "attn_backend": backend.attn_backend,
        "decode_kernel": args.decode_kernel,
        "requests": len(done),
        "kv_reuse": args.kv_reuse,
        "decode_steps": args.decode_steps,
        "includes_jit_compile": not args.warmup,
        **_latency_split(done),
        **_tbt_stats(batcher.workers),
        **_tick_stats(batcher.workers),
        "decode_tokens": int(n_toks),
        "throughput_tok_s": float(n_toks / max(total, 1e-9)),
        "pool_peak_pages": engine.pool.peak_pages,
        "pool_peak_utilization": round(
            engine.pool.peak_pages / max(stats.n_pages - 1, 1), 4
        ),
    }
    if engine.store is not None:
        out["block_store"] = engine.store.stats()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--engine",
        default="sim",
        choices=["sim", "jax"],
        help="sim: analytic cluster simulator; jax: real "
        "batched engine + paged KV pool on this host "
        "(--k > 1 runs the serving.cluster path: K "
        "engines over sharded item caches)",
    )
    ap.add_argument(
        "--k",
        type=int,
        default=None,
        help="instance count; default 40 for --engine sim, "
        "1 for --engine jax (pass --k N for the real "
        "multi-instance cluster)",
    )
    ap.add_argument("--qps", type=float, default=None)
    ap.add_argument("--requests", type=int, default=1500)
    ap.add_argument("--model", default="rcllm-qwen3-8b")
    ap.add_argument("--mode", default="rcllm", choices=["rcllm", "prefix", "full"])
    ap.add_argument(
        "--attn-backend",
        default="jnp",
        choices=["jnp", "pallas"],
        help="attention inside the jax engine's jitted steps: "
        "jnp reference, or the Pallas flash/selective "
        "kernels (interpret mode off-TPU)",
    )
    ap.add_argument(
        "--decode-kernel",
        default="auto",
        choices=["auto", "gather", "paged"],
        help="decode K/V read strategy: auto follows --attn-backend "
        "(pallas -> fused paged-attention kernel, jnp -> arena "
        "gather); gather/paged pin one path — decoded tokens are "
        "identical either way",
    )
    ap.add_argument(
        "--kv-reuse",
        default="off",
        choices=["off", "on"],
        help="cross-request beyond-prefix KV reuse: a shared "
        "ref-counted block store (pinned user tier + "
        "LRU item tier) over each engine's paged pool; "
        "decoded tokens are identical either way",
    )
    ap.add_argument(
        "--sched",
        default="wave",
        choices=["wave", "chunked"],
        help="scheduling discipline for the jax engine: wave = "
        "whole-prefill batches (prefill-prioritized); chunked = "
        "unified token-budget ticks mixing decode with "
        "chunk-resumable selective prefill.  Decoded tokens are "
        "bitwise identical either way",
    )
    ap.add_argument(
        "--chunk-tokens",
        type=int,
        default=128,
        help="prefill chunk size for --sched chunked (layer-0 "
        "scan dispatch width; multiples of 64 keep the jit "
        "shape grid small)",
    )
    ap.add_argument(
        "--step-tokens",
        type=int,
        default=None,
        help="per-tick token budget for --sched chunked "
        "(default: max(4 * chunk_tokens, 512))",
    )
    ap.add_argument(
        "--zipf-users",
        type=float,
        default=None,
        help="rcllm trace: draw user ids Zipf(a) instead of "
        "uniformly — heavy repeat users, the workload "
        "where --kv-reuse pays (e.g. 1.4)",
    )
    ap.add_argument(
        "--long-prompt-frac",
        type=float,
        default=0.0,
        help="rcllm trace: fraction of users carrying a lognormal "
        "heavy tail of extra reviews — long-prompt head-of-line "
        "interference, the workload where --sched chunked pays "
        "(e.g. 0.2)",
    )
    ap.add_argument("--policy", default="affinity")
    ap.add_argument("--r-item", type=float, default=0.3)
    ap.add_argument("--r-rev", type=float, default=0.3)
    # --engine jax knobs
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--prompt-tokens", type=int, default=160)
    ap.add_argument("--max-batch-tokens", type=int, default=4096)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=512)
    ap.add_argument(
        "--warmup",
        action="store_true",
        help="run a throwaway pass first so reported times "
        "exclude jit compilation",
    )
    args = ap.parse_args()

    if args.k is None:
        # 40 instances is the simulator's paper-scale default; a real
        # multi-engine cluster on this host must be asked for explicitly
        args.k = 1 if args.engine == "jax" else 40
    if args.engine == "jax":
        out = run_jax_cluster(args) if args.k > 1 else run_jax(args)
    else:
        out = run_sim(args)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
