"""Serving launcher: the distributed RcLLM cluster simulation.

    PYTHONPATH=src python -m repro.launch.serve --k 40 --qps 120

See examples/serve_cluster.py for the narrated version; this entry point
emits machine-readable JSON.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=40)
    ap.add_argument("--qps", type=float, default=None)
    ap.add_argument("--requests", type=int, default=1500)
    ap.add_argument("--model", default="rcllm-qwen3-8b")
    ap.add_argument("--mode", default="rcllm",
                    choices=["rcllm", "prefix", "full"])
    ap.add_argument("--policy", default="affinity")
    ap.add_argument("--r-item", type=float, default=0.3)
    ap.add_argument("--r-rev", type=float, default=0.3)
    args = ap.parse_args()

    qps = args.qps if args.qps is not None else 3.0 * args.k
    cfg = REG.ARCHS[args.model]
    reqs, placement, _ = SIM.make_sim_setup(k=args.k,
                                            n_requests=args.requests,
                                            qps=qps, n_items=8000, seed=1)
    res = SIM.simulate(cfg, CM.V5E_1, reqs, placement,
                       SIM.SimConfig(mode=args.mode, policy=args.policy,
                                     r_item=args.r_item, r_rev=args.r_rev))
    print(json.dumps({"k": args.k, "qps": qps, "mode": args.mode,
                      "policy": args.policy, **res.summary()}, indent=1))


if __name__ == "__main__":
    main()
