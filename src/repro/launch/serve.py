"""Serving launcher: cluster simulation or the real batched JAX engine.

    # distributed cluster simulation (analytic cost model, K instances)
    PYTHONPATH=src python -m repro.launch.serve --config engine=sim,k=40 \\
        --qps 120

    # real hardware: continuous batching + paged KV pool on one instance
    PYTHONPATH=src python -m repro.launch.serve --config engine=jax \\
        --requests 8

    # real hardware, K instances: affinity-scheduled cluster of JAX
    # engines over sharded item caches (per-request TTFT, per-worker
    # hit rates, explicit cross-shard transfers)
    PYTHONPATH=src python -m repro.launch.serve --config engine=jax,k=4 \\
        --requests 12

    # unified token-budget scheduler: chunk-resumable selective prefill
    # mixed with decode in every tick (no whole-prefill waves)
    PYTHONPATH=src python -m repro.launch.serve \\
        --config engine=jax,sched=chunked,chunk_tokens=128 \\
        --requests 12 --long-prompt-frac 0.2

    # the asyncio session server: the same trace as live streaming
    # sessions (per-tick online metrics in the output's "online" key)
    PYTHONPATH=src python -m repro.launch.serve --server \\
        --config engine=jax,sched=chunked,kv_reuse=on --requests 12

    # tensor-parallel serving on a real jax mesh (2 devices on the model
    # axis; on CPU, force host devices before the first jax import)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.launch.serve \\
        --config mesh.tp=2,sched=chunked --requests 8

Serving knobs live in ONE typed object — `serving.api.ServeConfig` —
passed as ``--config key=value[,key=value...]`` and validated up front
(invalid combos like ``decode_kernel=paged`` with ``engine=sim`` fail
with a message naming both knobs).  The historical per-knob flags
(``--engine --k --sched --kv-reuse ...``) still work: they fold into
the same dataclass through `ServeConfig.from_args` with a single
`DeprecationWarning`.  Workload shape (``--requests --qps --zipf-users
--long-prompt-frac``) and launcher behaviour (``--warmup --server
--speed``) stay first-class flags — they describe the experiment, not
the serving stack.

All paths drive the *same* batching loop; ``engine`` picks the backend
behind its seam (`serving.batching.EngineBackend`) and ``k`` with
``engine=jax`` picks single-instance vs the `serving.cluster` path.
``sched`` picks the scheduling discipline: ``wave`` (whole-prefill
batches, prefill-prioritized — the default) or ``chunked`` (every tick
packs decode tokens plus fixed-size prefill chunks under a global token
budget; decoded tokens are bitwise identical either way).  With
``mode=rcllm`` each prompt goes through decomposition → assembly
plan → beyond-prefix cache insertion → selective recompute → paged
decode; ``mode=full`` is the Full-Recompute reference.  ``--server``
re-expresses the trace-driven run as a thin client of the asyncio
session server (`serving.server`): identical output schema (and, with
``--speed 0``, bitwise-identical decoded tokens) plus the server's
rolling online metrics.  This entry point emits machine-readable JSON,
including a per-request latency split (queue-wait vs prefill-compute vs
decode) and time-between-tokens percentiles so scheduler changes are
attributable from bench artifacts.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import registry as REG
from repro.core import cost_model as CM
from repro.core import simulator as SIM
from repro.serving.api import ServeConfig, SubmitRequest


def run_sim(config: ServeConfig, args) -> dict:
    qps = args.qps if args.qps is not None else 3.0 * config.k
    cfg = REG.ARCHS[args.model]
    reqs, placement, _ = SIM.make_sim_setup(
        k=config.k, n_requests=args.requests, qps=qps, n_items=8000, seed=1
    )
    res = SIM.simulate(
        cfg,
        CM.V5E_1,
        reqs,
        placement,
        SIM.SimConfig(
            mode=config.mode,
            policy=config.policy,
            r_item=config.r_item,
            r_rev=config.r_rev,
        ),
    )
    return {
        "engine": "sim",
        "k": config.k,
        "qps": qps,
        "mode": config.mode,
        "policy": config.policy,
        **res.summary(),
    }


def _percentiles(xs, qs=(50, 90, 99)) -> dict:
    xs = np.asarray(list(xs), np.float64)
    if len(xs) == 0:
        return {f"p{q}_s": None for q in qs}
    return {f"p{q}_s": float(np.percentile(xs, q)) for q in qs}


def _latency_split(completions) -> dict:
    """Per-request latency attribution + aggregates from completions."""
    done = sorted(completions, key=lambda c: c.rid)
    if not done:
        # every session was rejected/cancelled before producing a token
        # (the server path degrades per-request instead of raising)
        keys = ("ttft_p50_s", "ttft_p90_s", "ttft_p99_s", "ttft_mean_s")
        out = {k: None for k in keys}
        out.update(queue_wait_mean_s=None, prefill_mean_s=None, decode_mean_s=None)
        out["per_request"] = []
        return out
    ttft = np.asarray([c.first_token_s - c.arrival_s for c in done])
    return {
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p90_s": float(np.percentile(ttft, 90)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "ttft_mean_s": float(ttft.mean()),
        "queue_wait_mean_s": float(np.mean([c.queue_wait_s for c in done])),
        "prefill_mean_s": float(np.mean([c.prefill_s for c in done])),
        "decode_mean_s": float(np.mean([c.decode_s for c in done])),
        "per_request": [
            {
                "rid": c.rid,
                "ttft_s": round(float(c.first_token_s - c.arrival_s), 4),
                "queue_wait_s": round(float(c.queue_wait_s), 4),
                "prefill_s": round(float(c.prefill_s), 4),
                "decode_s": round(float(c.decode_s), 4),
            }
            for c in done
        ],
    }


def _mesh_info(config: ServeConfig):
    """The mesh the run actually used, for the output JSON (None when
    the config runs the classic unsharded path)."""
    if not config.mesh.enabled:
        return None
    import jax

    return {
        "tp": config.mesh.tp,
        "dp": config.mesh.dp,
        "shape": list(config.mesh.resolved_shape),
        "axis_names": list(config.mesh.axis_names),
        "host_devices": len(jax.devices()),
    }


def _tbt_stats(workers) -> dict:
    samples = [dt for w in workers for dt in w.tbt]
    out = {f"tbt_{k}": v for k, v in _percentiles(samples).items()}
    out["tbt_samples"] = len(samples)
    return out


def _tick_stats(workers) -> dict:
    ticks = [t for w in workers for t in w.ticks]
    if not ticks:
        return {}
    return {
        "ticks": len(ticks),
        "oversized_ticks": sum(1 for t in ticks if t.oversized),
        "mean_tick_tokens": float(
            np.mean(
                [t.decode_tokens + t.chunk_tokens + t.finalize_tokens for t in ticks]
            )
        ),
    }


def run_jax_cluster(config: ServeConfig, args) -> dict:
    """K real engine workers behind the Eq. 2 scheduler (serving.cluster)."""
    from repro.core.rcllm import make_tiny_system
    from repro.data import synth as SY
    from repro.serving.cluster import ClusterEngine

    qps = args.qps if args.qps is not None else 8.0
    system, pool_rv, prof, _ = make_tiny_system(
        n_items=80, n_requests_hist=40, k_instances=config.k,
        n_layers=2, d_model=32,
    )
    trace = SY.make_trace(
        system.catalog,
        pool_rv,
        prof,
        args.requests,
        qps=qps,
        n_users=max(3, args.requests // 2),
        n_candidates=8,
        reviews_per_user=1,
        seed=2,
        user_zipf_a=args.zipf_users,
        long_prompt_frac=args.long_prompt_frac,
    )

    if args.warmup:
        ClusterEngine(system, config).run(trace, decode_steps=config.decode_steps)
    cluster = ClusterEngine(system, config)
    rep = cluster.run(trace, decode_steps=config.decode_steps)

    ttft = rep.ttft()
    return {
        "engine": "jax-cluster",
        "k": config.k,
        "mode": config.mode,
        "sched": config.sched,
        "attn_backend": config.attn_backend,
        "decode_kernel": config.decode_kernel,
        "kv_reuse": "on" if config.kv_reuse else "off",
        "mesh": _mesh_info(config),
        "disagg": (
            {
                "prefill_workers": config.disagg.prefill_workers,
                "decode_workers": config.disagg.decode_workers,
                "mig_gamma": config.disagg.mig_gamma,
            }
            if config.disagg.enabled
            else None
        ),
        "store": (
            {
                "kv_store_dtype": config.store.kv_store_dtype,
                "spill_mb": config.store.spill_mb,
                "prefetch_pages_per_tick": config.store.prefetch_pages_per_tick,
            }
            if config.store.enabled
            else None
        ),
        "policy": rep.policy,
        "requests": len(rep.completions),
        "decode_steps": config.decode_steps,
        "includes_jit_compile": not args.warmup,
        "per_request_ttft_s": [round(float(x), 4) for x in ttft],
        **_latency_split(rep.completions),
        **_tbt_stats(cluster.batcher.workers),
        **_tick_stats(cluster.batcher.workers),
        "mean_hit_rate": rep.mean_hit_rate(),
        "per_worker": [
            {
                "worker": w.worker,
                "role": (
                    config.disagg.role_of(w.worker)
                    if config.disagg.enabled
                    else "unified"
                ),
                "requests": w.n_requests,
                "mean_hit_rate": (
                    round(w.mean_hit_rate, 4)
                    if w.mean_hit_rate is not None
                    else None
                ),
                "transfer_blocks": w.transfer_blocks,
                "transfer_tokens": w.transfer_tokens,
                "transfer_mbytes": round(w.transfer_bytes / 1e6, 3),
                "transfer_seconds": round(w.transfer_seconds, 6),
                "pool_peak_pages": w.pool_peak_pages,
                "busy_seconds": round(w.busy_seconds, 4),
                "preempted": w.preempted,
                "migrations": w.migrations,
                "migrated_out": w.migrated_out,
                "migrated_pages": w.migrated_pages,
                "migration_mbytes": round(w.migration_bytes / 1e6, 3),
                "migration_s": round(w.migration_s, 6),
                "migration_digest_hits": w.migration_digest_hits,
                "device_blocks": w.device_blocks,
                "spill_blocks": w.spill_blocks,
                "spill_hits": w.spill_hits,
                "prefetch_promotions": w.prefetch_promotions,
                "dequant_s": round(w.dequant_s, 6),
                "kv_reuse": w.kv_reuse,
            }
            for w in rep.workers
        ],
    }


def _jax_workload(config: ServeConfig, args):
    """Build (params, lm_cfg, requests, plans, reuse) for the single-
    instance jax paths — shared by the closed-loop runner and the
    session server so both serve the exact same trace."""
    from repro.serving.batching import PendingRequest
    from repro.serving.workload import rcllm_workload

    if args.zipf_users is not None and config.mode != "rcllm":
        raise SystemExit(
            "--zipf-users shapes the rcllm trace; it has no "
            "effect on mode=full prompts"
        )
    qps = args.qps if args.qps is not None else 8.0
    rng = np.random.default_rng(1)
    plans = {}
    reuse = None

    if config.mode == "rcllm":
        # full RcLLM stack: tiny model + both cache pools + placement
        from repro.core.rcllm import make_tiny_system
        from repro.data import synth as SY
        from repro.serving.workload import rcllm_reuse_info

        system, pool_rv, prof, _ = make_tiny_system(
            n_items=80, n_requests_hist=40, k_instances=max(config.k, 1),
            n_layers=2, d_model=32,
        )
        params, cfg = system.params, system.cfg
        # one trace producer for every flag combination: --zipf-users
        # changes ONLY the user-id distribution and --long-prompt-frac
        # ONLY the history-length tail, so scheduler / reuse comparisons
        # are not confounded by trace shape
        trace = SY.make_trace(
            system.catalog,
            pool_rv,
            prof,
            args.requests,
            qps=qps,
            n_users=max(3, args.requests // 2),
            n_candidates=8,
            reviews_per_user=1,
            seed=2,
            user_zipf_a=args.zipf_users,
            long_prompt_frac=args.long_prompt_frac,
        )
        reqs, plans = rcllm_workload(system, trace, decode_steps=config.decode_steps)
        if config.kv_reuse:
            reuse = rcllm_reuse_info(system, trace, plans)
    else:
        # Full-Recompute reference on random prompts
        import jax

        from repro.configs.base import LMConfig
        from repro.models import transformer as T

        cfg = LMConfig(
            name="serve-tiny",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            mlp_type="swiglu",
            dtype="float32",
            attn_q_chunk=64,
            attn_kv_chunk=64,
            remat=False,
        )
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        if args.prompt_tokens < 16:
            raise SystemExit("--prompt-tokens must be >= 16")
        lo = min(48, args.prompt_tokens)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, args.requests))
        reqs = []
        for rid in range(args.requests):
            n = int(rng.integers(lo, args.prompt_tokens + 1))
            reqs.append(
                PendingRequest(
                    arrival_s=float(arrivals[rid]),
                    rid=rid,
                    n_tokens=n,
                    decode_steps=config.decode_steps,
                    tokens=rng.integers(1, cfg.vocab_size, n).astype(np.int32),
                )
            )
    return params, cfg, reqs, plans, reuse


def _engine_report(config: ServeConfig, args, engine, backend, done) -> dict:
    total = max((c.done_s for c in done), default=0.0)
    n_toks = sum(len(backend.generated[c.rid]) for c in done)
    stats = engine.pool.stats()
    out = {
        "engine": "jax",
        "mode": config.mode,
        "sched": config.sched,
        "attn_backend": backend.attn_backend,
        "decode_kernel": config.decode_kernel,
        "requests": len(done),
        "kv_reuse": "on" if config.kv_reuse else "off",
        "mesh": _mesh_info(config),
        "decode_steps": config.decode_steps,
        "includes_jit_compile": not args.warmup,
        **_latency_split(done),
        "decode_tokens": int(n_toks),
        "throughput_tok_s": float(n_toks / max(total, 1e-9)),
        "pool_peak_pages": engine.pool.peak_pages,
        "pool_peak_utilization": round(
            engine.pool.peak_pages / max(stats.n_pages - 1, 1), 4
        ),
    }
    if engine.store is not None:
        out["block_store"] = engine.store.stats()
    return out


def run_jax(config: ServeConfig, args) -> dict:
    """Continuous batching over the real engine on this host's devices."""
    from repro.core import engine as ENG
    from repro.serving import api as API

    params, cfg, reqs, plans, reuse = _jax_workload(config, args)
    sel = ENG.SelectiveConfig(r_item=config.r_item, r_rev=config.r_rev, window=16)

    def make_batcher():
        engine = API.build_engine(params, cfg, config, sel=sel)
        backend = API.build_backend(engine, config, plans=plans, reuse=reuse)
        return engine, backend, API.build_batcher(backend, config)

    if args.warmup:
        # throwaway pass to fill the jit caches, so the reported times
        # are step times rather than trace/compile times
        make_batcher()[2].run(list(reqs))
    engine, backend, batcher = make_batcher()
    done = sorted(batcher.run(reqs), key=lambda c: c.rid)

    out = _engine_report(config, args, engine, backend, done)
    out.update(_tbt_stats(batcher.workers))
    out.update(_tick_stats(batcher.workers))
    return out


def run_jax_server(config: ServeConfig, args) -> dict:
    """The same single-instance trace served through the asyncio session
    server: streaming sessions over the identical scheduling loop, plus
    rolling online metrics.  ``--speed 0`` replays the trace's arrival
    stamps deterministically (decoded tokens bitwise-identical to
    `run_jax`); ``--speed > 0`` turns it into open-loop wall-clock
    traffic."""
    from repro.core import engine as ENG
    from repro.serving import api as API
    from repro.serving.server import AsyncSessionServer, serve_trace

    params, cfg, reqs, plans, reuse = _jax_workload(config, args)
    sel = ENG.SelectiveConfig(r_item=config.r_item, r_rev=config.r_rev, window=16)
    submits = [
        (
            r.arrival_s,
            SubmitRequest(
                rid=r.rid,
                tokens=r.tokens,
                max_tokens=r.decode_steps,
                context=plans.get(r.rid),
                reuse=(reuse or {}).get(r.rid),
            ),
        )
        for r in reqs
    ]

    def make_server():
        engine = API.build_engine(params, cfg, config, sel=sel)
        backend = API.build_backend(engine, config)
        return engine, backend, AsyncSessionServer(backend, config)

    if args.warmup:
        import asyncio

        from repro.serving.server import replay

        engine, backend, server = make_server()
        asyncio.run(replay(server, submits, speed=args.speed))
    engine, backend, _ = make_server()
    completions, server = serve_trace(backend, config, submits, speed=args.speed)
    # the worker's completion records carry the same virtual-clock
    # latency split the closed-loop runner reports
    done = sorted(server.worker.done, key=lambda c: c.rid)

    out = _engine_report(config, args, engine, backend, done)
    out.update(_tbt_stats([server.worker]))
    out.update(_tick_stats([server.worker]))
    out["server"] = True
    out["speed"] = args.speed
    out["finish_reasons"] = {
        reason: sum(1 for c in completions.values() if c.reason == reason)
        for reason in sorted({c.reason for c in completions.values()})
    }
    out["online"] = server.metrics_snapshot()
    return out


def build_config(args) -> ServeConfig:
    """``--config`` + legacy per-knob flags -> one validated ServeConfig."""
    if args.config is not None:
        base = ServeConfig.parse(args.config)
    else:
        # historical defaults: engine=sim with 40 simulated instances;
        # --engine jax serves one real instance unless --k asks for more
        eng = args.engine if args.engine is not None else "sim"
        k = args.k if args.k is not None else (1 if eng == "jax" else 40)
        base = ServeConfig(engine=eng, k=k)
    return ServeConfig.from_args(args, base=base)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--config",
        default=None,
        help="serving stack as key=value[,key=value...] over "
        "serving.api.ServeConfig — e.g. "
        "engine=jax,k=2,sched=chunked,kv_reuse=on.  The typed "
        "replacement for the per-knob flags below",
    )
    ap.add_argument(
        "--server",
        action="store_true",
        help="drive the trace through the asyncio session server "
        "(serving.server; engine=jax, k=1): streaming sessions over "
        "the same scheduling loop, online metrics in the output's "
        "'online' key.  Identical decoded tokens at --speed 0",
    )
    ap.add_argument(
        "--speed",
        type=float,
        default=0.0,
        help="--server arrival pacing: 0 = deterministic replay of "
        "the trace's arrival stamps; >0 = open-loop wall-clock "
        "arrivals at trace-time / speed",
    )
    # ------- workload / launcher flags (first-class, not deprecated) -------
    ap.add_argument("--qps", type=float, default=None)
    ap.add_argument("--requests", type=int, default=1500)
    ap.add_argument("--model", default="rcllm-qwen3-8b")
    ap.add_argument(
        "--zipf-users",
        type=float,
        default=None,
        help="rcllm trace: draw user ids Zipf(a) instead of "
        "uniformly — heavy repeat users, the workload "
        "where kv_reuse pays (e.g. 1.4)",
    )
    ap.add_argument(
        "--long-prompt-frac",
        type=float,
        default=0.0,
        help="rcllm trace: fraction of users carrying a lognormal "
        "heavy tail of extra reviews — long-prompt head-of-line "
        "interference, the workload where sched=chunked pays "
        "(e.g. 0.2)",
    )
    ap.add_argument("--prompt-tokens", type=int, default=160)
    ap.add_argument(
        "--warmup",
        action="store_true",
        help="run a throwaway pass first so reported times "
        "exclude jit compilation",
    )
    # ---- legacy per-knob serving flags (deprecated: they fold into the ----
    # ---- ServeConfig with one DeprecationWarning; prefer --config) -------
    ap.add_argument("--engine", default=None, choices=["sim", "jax"])
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--mode", default=None, choices=["rcllm", "prefix", "full"])
    ap.add_argument("--attn-backend", default=None, choices=["jnp", "pallas"])
    ap.add_argument(
        "--decode-kernel", default=None, choices=["auto", "gather", "paged"]
    )
    ap.add_argument("--kv-reuse", default=None, choices=["off", "on"])
    ap.add_argument("--sched", default=None, choices=["wave", "chunked"])
    ap.add_argument("--chunk-tokens", type=int, default=None)
    ap.add_argument("--step-tokens", type=int, default=None)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--r-item", type=float, default=None)
    ap.add_argument("--r-rev", type=float, default=None)
    ap.add_argument("--decode-steps", type=int, default=None)
    ap.add_argument("--max-batch-tokens", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=None)
    ap.add_argument("--pages", type=int, default=None)
    args = ap.parse_args(argv)

    try:
        config = build_config(args)
    except ValueError as e:
        raise SystemExit(str(e))

    if args.server:
        if config.engine != "jax":
            raise SystemExit("--server drives the real engine: engine=jax")
        if config.k != 1:
            raise SystemExit(
                "--server runs a single-worker session server (k=1); "
                "multi-worker serving is the closed-loop cluster path"
            )
        out = run_jax_server(config, args)
    elif config.engine == "jax":
        out = run_jax_cluster(config, args) if config.k > 1 else run_jax(config, args)
    else:
        out = run_sim(config, args)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
