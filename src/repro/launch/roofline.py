"""Roofline term derivation from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak)   [per-device module → ÷1 chip]
memory term     = HLO_bytes / HBM_bw
collective term = collective_bytes / link_bw

``cost_analysis()`` runs on the *partitioned per-device* module, so flops /
bytes are already per-chip.  Collective bytes are NOT in cost_analysis —
we parse the optimized HLO and sum collective operand/output sizes with a
per-op-type wire multiplier (ring all-reduce moves ≈2× the buffer).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI; DCN between pods ≈ 25 GB/s per host (used by the simulator, not here).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")

# wire-traffic multiplier per collective (ring algorithms, per device)
_COLL_OPS = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in optimized HLO,
    weighted by the wire multiplier.  Returns per-op-type and total bytes."""
    out: Dict[str, float] = {k: 0.0 for k in _COLL_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            m = re.search(r"=\s*((?:\([^)]*\))|(?:\S+))\s+([a-z\-]+)\(", ls)
            if not m:
                continue
            shape_txt, op = m.group(1), m.group(2)
            # "all-reduce-start"/-done variants
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLL_OPS and "-done" not in op:
                out[base] += _shape_bytes(shape_txt) * _COLL_OPS[base]
                counts[base] += 1
    total = sum(out.values())
    return {"per_op_bytes": out, "counts": counts, "total_bytes": total}


def roofline_terms(rec: dict) -> dict:
    """rec: a dry-run record with flops_per_device / bytes_per_device /
    collectives.  Returns the three terms in seconds + the bottleneck."""
    ct = rec["flops_per_device"] / PEAK_FLOPS
    mt = rec["bytes_per_device"] / HBM_BW
    xt = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute_s": ct, "memory_s": mt, "collective_s": xt}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    denom = max(ct, mt, xt)
    terms["roofline_fraction_of_dominant"] = (
        ct / denom if denom > 0 else 0.0)
    return terms


def model_flops(arch: str, shape_dims: dict, step: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE), 2·N·D for
    forward-only steps — the 'useful compute' yardstick."""
    from repro.configs import registry as R
    cfg = R.ARCHS[arch]
    fam = R.family_of(arch)
    if fam != "lm":
        return float("nan")
    n = cfg.active_param_count()
    if step == "train":
        toks = shape_dims["batch"] * shape_dims["seq"]
        return 6.0 * n * toks
    if step == "prefill":
        toks = shape_dims["batch"] * shape_dims["seq"]
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape_dims["batch"]
