import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver — three cells, hypothesis → change → measure.

Cells (per the selection rules):
  A nemotron-4-15b × prefill_32k  — most representative of the paper
    (TTFT-critical prefill; the paper's selective recomputation applies)
  B kimi-k2-1t-a32b × train_4k    — most collective-bound (9.5 s TP wire)
  C moonshot-v1-16b-a3b × train_4k — worst roofline fraction (0.35)

Each iteration records hypothesis, the analytic roofline delta, and —
where the change alters the compiled artifact — the measured HLO evidence
(collective op counts/bytes, temp memory).  Results land in results/perf/.
"""
import dataclasses          # noqa: E402
import json                 # noqa: E402
import time                 # noqa: E402

import jax                  # noqa: E402

from repro.configs import registry as R                        # noqa: E402
from repro.launch import steps as STEPS                        # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.launch.roofline import collective_bytes_from_hlo    # noqa: E402
from repro.launch.roofline_analytic import lm_analytic         # noqa: E402


def compile_probe(arch, shape, mesh=None, cfg_override=None):
    """Lower+compile a (possibly modified) cell; return HLO evidence."""
    mesh = mesh or make_production_mesh(shape=(16, 16))  # the fixed v5e pod
    if cfg_override is not None:
        old = R.ARCHS[arch]
        R.ARCHS[arch] = cfg_override
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh = STEPS.build(arch, shape, mesh)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        mem = compiled.memory_analysis()
        coll = collective_bytes_from_hlo(compiled.as_text())
        return {"compile_s": round(time.time() - t0, 1),
                "temp_gb": round(mem.temp_size_in_bytes / 1e9, 2),
                "arg_gb": round(mem.argument_size_in_bytes / 1e9, 2),
                "collective_counts": coll["counts"],
                "collective_bytes_hlo": coll["total_bytes"]}
    finally:
        if cfg_override is not None:
            R.ARCHS[arch] = old


def fmt(t):
    return (f"comp={t['compute_s']:.3f}s mem={t['memory_s']:.3f}s "
            f"coll={t['collective_s']:.3f}s -> step≈{t['overlapped_s']:.3f}s "
            f"[{t['bottleneck']}]")


def cell_A(out, probe: bool):
    arch, shape = "nemotron-4-15b", "prefill_32k"
    cfg = R.ARCHS[arch]
    dims = R.shapes_of(arch)[shape].dims
    log = []
    base = lm_analytic(cfg, "prefill", dims)
    log.append({"iter": 0, "name": "baseline (full prefill, masked tiles)",
                "terms": base})

    t1 = lm_analytic(cfg, "prefill", dims, selective_recompute=0.37)
    log.append({
        "iter": 1, "name": "RcLLM selective recomputation (paper, r=0.37)",
        "hypothesis": "layers 1..L-1 run dense+attention only for the "
                      "recompute set (instr+HH+window+misses ≈ 37% of "
                      "tokens); compute term ≈ (1 + 0.37·(L-1))/L ≈ 0.39×",
        "terms": t1,
        "confirmed": t1["compute_s"] / base["compute_s"] < 0.45})

    t2 = lm_analytic(cfg, "prefill", dims, selective_recompute=0.37,
                     causal_block_pairing=True)
    log.append({
        "iter": 2, "name": "+ causal block pairing (beyond-paper)",
        "hypothesis": "the baseline masks acausal tiles but still computes "
                      "them; enumerating live (q,kv) tile pairs cuts "
                      "attention-score flops to ~0.55× (diag + lower tiles)",
        "terms": t2,
        "confirmed": t2["compute_s"] < t1["compute_s"]})

    t3 = lm_analytic(cfg, "prefill", dims, selective_recompute=0.37,
                     causal_block_pairing=True, seq_parallel=True,
                     overlap_collectives=True)
    log.append({
        "iter": 3, "name": "+ SP boundaries + comm/compute overlap",
        "hypothesis": "prefill TP all-reduces become RS/AG over "
                      "seq-sharded boundaries (0.5× wire) and overlap the "
                      "per-layer matmuls; step ≈ max(comp, coll)",
        "terms": t3,
        "confirmed": t3["overlapped_s"] < t2["serial_s"]})
    if probe:
        cfg_bp = dataclasses.replace(cfg, causal_block_pairing=True,
                                     attn_q_chunk=2048, attn_kv_chunk=2048)
        log.append({"iter": "evidence",
                    "name": "compile probe: block-pairing lowers (2048-tiles)",
                    "probe": compile_probe(arch, shape, cfg_override=cfg_bp)})
    out["A_nemotron_prefill_32k"] = {
        "selection": "most representative of the paper's technique",
        "final_speedup_vs_baseline":
            base["serial_s"] / log[3]["terms"]["overlapped_s"],
        "iterations": log}


def cell_B(out, probe: bool):
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    cfg = R.ARCHS[arch]
    dims = R.shapes_of(arch)[shape].dims
    log = []
    base = lm_analytic(cfg, "train", dims)
    log.append({"iter": 0, "name": "baseline", "terms": base})

    t1 = lm_analytic(cfg, "train", dims, seq_parallel=True)
    log.append({
        "iter": 1, "name": "sequence-parallel TP boundaries",
        "hypothesis": "TP wire dominates (4 AR of (B_loc,S,D) per layer = "
                      "458 GB/dev/step); RS+AG over seq-sharded residuals "
                      "halves wire bytes → collective term ×0.5",
        "terms": t1,
        "confirmed": abs(t1["collective_s"] / base["collective_s"] - 0.5
                         - 0.0) < 0.2})

    t2 = lm_analytic(cfg, "train", dims, seq_parallel=True,
                     overlap_collectives=True)
    log.append({
        "iter": 2, "name": "+ async collectives overlapped with compute",
        "hypothesis": "remaining 4.8 s of wire can hide behind the 5.5 s "
                      "of expert GEMMs (XLA latency-hiding scheduler); "
                      "step time → max(comp, coll) ≈ comp",
        "terms": t2,
        "confirmed": t2["overlapped_s"] <= t1["serial_s"] * 0.65})

    t3 = lm_analytic(cfg, "train", dims, seq_parallel=True,
                     overlap_collectives=True, causal_block_pairing=True)
    log.append({
        "iter": 3, "name": "+ causal block pairing",
        "hypothesis": "with wire hidden, compute is dominant again; "
                      "attention tiles are ~23% of train flops at S=4096 → "
                      "expect ~10% off the compute term",
        "terms": t3,
        "confirmed": t3["compute_s"] < t2["compute_s"]})
    if probe:
        log.append({"iter": "evidence",
                    "name": "compile probe: baseline collective schedule",
                    "probe": compile_probe(arch, shape)})
    out["B_kimi_train_4k"] = {
        "selection": "most collective-bound (9.53 s wire/step at baseline)",
        "final_speedup_vs_baseline":
            base["serial_s"] / log[3]["terms"]["overlapped_s"],
        "iterations": log}


def cell_C(out, probe: bool):
    arch, shape = "moonshot-v1-16b-a3b", "train_4k"
    cfg = R.ARCHS[arch]
    dims = R.shapes_of(arch)[shape].dims
    log = []
    base = lm_analytic(cfg, "train", dims)
    log.append({"iter": 0, "name": "baseline mesh (16,16)", "terms": base})

    t1 = lm_analytic(cfg, "train", dims, data_par=64)
    log.append({
        "iter": 1, "name": "mesh reshape (16,16) -> (64,4)",
        "hypothesis": "d_model=2048 is too small for TP=16 (128 cols/shard "
                      "starves the MXU and the per-layer AR volume is paid "
                      "16× over); TP=4/DP=64 cuts activation wire 4× while "
                      "experts (64) still shard over model=4",
        "terms": t1,
        "confirmed": t1["collective_s"] < base["collective_s"] * 0.3})

    t2 = lm_analytic(cfg, "train", dims, data_par=64, seq_parallel=True)
    log.append({
        "iter": 2, "name": "+ sequence-parallel boundaries",
        "hypothesis": "remaining TP wire halves again",
        "terms": t2, "confirmed": t2["collective_s"] < t1["collective_s"]})

    t3 = lm_analytic(cfg, "train", dims, data_par=64, seq_parallel=True,
                     overlap_collectives=True, causal_block_pairing=True)
    log.append({
        "iter": 3, "name": "+ overlap + block pairing",
        "terms": t3,
        "confirmed": t3["overlapped_s"] < t2["serial_s"]})
    if probe:
        mesh64 = jax.make_mesh((64, 4), ("data", "model"),
                               devices=jax.devices()[:256])
        log.append({"iter": "evidence",
                    "name": "compile probe: (64,4) mesh lowers + memory",
                    "probe": compile_probe(arch, shape, mesh=mesh64)})
    out["C_moonshot_train_4k"] = {
        "selection": "worst roofline fraction (0.35 at baseline)",
        "final_speedup_vs_baseline":
            base["serial_s"] / log[3]["terms"]["overlapped_s"],
        "iterations": log}


def cell_D(out):
    """Bonus cell (beyond the required three): gemma-7b × long_500k — the
    paper's selective read set applied to long-context decode."""
    arch, shape = "gemma-7b", "long_500k"
    cfg = R.ARCHS[arch]
    dims = R.shapes_of(arch)[shape].dims
    log = []
    base = lm_analytic(cfg, "decode", dims)
    log.append({"iter": 0, "name": "baseline (full KV read)", "terms": base})
    rd = (256 + int(0.05 * dims["seq"])) / dims["seq"]   # window ∪ 5% HH
    t1 = lm_analytic(cfg, "decode", dims, selective_decode_read=rd)
    log.append({
        "iter": 1,
        "name": f"RcLLM selective read set (window 256 + 5% HH, rd={rd:.3f})",
        "hypothesis": "decode at B=1/S=524288 is KV-read-bound (cache "
                      "dwarfs params at this config); restricting reads to "
                      "window ∪ heavy hitters cuts the kv term ~20×, "
                      "leaving the param-read floor",
        "terms": t1,
        "confirmed": t1["memory_s"] < base["memory_s"] * 0.5})
    out["D_gemma_long_500k"] = {
        "selection": "bonus: paper technique on the long-context decode cell",
        "final_speedup_vs_baseline": base["serial_s"] / t1["serial_s"],
        "iterations": log}


def main(probe: bool = True):
    out = {}
    cell_A(out, probe)
    cell_B(out, probe)
    cell_C(out, probe)
    cell_D(out)
    os.makedirs("results/perf", exist_ok=True)
    with open("results/perf/hillclimbs.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    for cell, rec in out.items():
        print(f"== {cell} ({rec['selection']}) ==")
        for it in rec["iterations"]:
            if "terms" in it:
                print(f"  [{it['iter']}] {it['name']}: {fmt(it['terms'])}"
                      + (f"  confirmed={it['confirmed']}"
                         if "confirmed" in it else ""))
            else:
                print(f"  [{it['iter']}] {it['name']}: {it['probe']}")
        print(f"  final speedup vs baseline: "
              f"{rec['final_speedup_vs_baseline']:.2f}x")
    return out


if __name__ == "__main__":
    import sys
    main(probe="--no-probe" not in sys.argv)
