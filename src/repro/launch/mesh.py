"""Mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  `make_production_mesh()` derives its shape
from the devices actually present: ``len(jax.devices())`` is factored
into (data, model) with the model axis the largest divisor not
exceeding sqrt(n), so 8 host devices become a (4, 2) mesh and 256 chips
a (16, 16) pod.  Multi-pod prepends a ``pod`` axis of 2 (an outer
data-parallel dimension whose collectives cross DCN).  Callers modeling
a *specific* production topology (the dry-run's 16×16 v5e pod, the
serving `MeshConfig`) pass ``shape=`` explicitly; an explicit shape
larger than the host raises with the XLA_FLAGS hint.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def factor_devices(n: int) -> Tuple[int, int]:
    """Factor n into (data, model) with model the largest divisor of n
    that does not exceed sqrt(n) — so data >= model and data*model == n
    (n=8 -> (4, 2), n=256 -> (16, 16), a prime n -> (n, 1))."""
    model = 1
    for d in range(1, int(n**0.5) + 1):
        if n % d == 0:
            model = d
    return n // model, model


def make_production_mesh(
    *,
    multi_pod: bool = False,
    shape: Optional[Sequence[int]] = None,
    axis_names: Optional[Sequence[str]] = None,
    devices=None,
):
    devices = list(jax.devices()) if devices is None else list(devices)
    if shape is None:
        n_avail = len(devices)
        if multi_pod:
            if n_avail % 2:
                raise RuntimeError(
                    f"multi-pod mesh needs an even device count to split "
                    f"across 2 pods, found {n_avail}"
                )
            data, model = factor_devices(n_avail // 2)
            shape = (2, data, model)
        else:
            data, model = factor_devices(n_avail)
            shape = (data, model)
    shape = tuple(int(s) for s in shape)
    if axis_names is None:
        axis_names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    axis_names = tuple(axis_names)
    if len(axis_names) != len(shape):
        raise ValueError(
            f"mesh shape {shape} has {len(shape)} dims but axis_names="
            f"{axis_names} has {len(axis_names)}"
        )
    n = 1
    for s in shape:
        n *= s
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            f"dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            f"=512 before importing jax"
        )
    return jax.make_mesh(shape, axis_names, devices=devices[:n])


def data_axes(mesh) -> tuple:
    """The axes batch-like dimensions shard over ('pod' included if present)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
