"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod = 16×16 (256 v5e chips, axes
data×model); multi-pod adds a leading `pod` axis (2×16×16 = 512 chips) that
acts as an outer data-parallel dimension whose collectives cross DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            f"dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            f"=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def data_axes(mesh) -> tuple:
    """The axes batch-like dimensions shard over ('pod' included if present)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
